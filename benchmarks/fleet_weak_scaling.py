"""Weak-scaling fleet: tuned interfaces/sec vs device count, fixed
per-device load — plus the largest fleet the box holds.

DIAL's decentralization makes the fused decision loop embarrassingly
partitionable along the interface/batch axis: every decision reads only
its own interface's local counters, so sharding the batch over a 1-D
mesh (``FusedLoop(mesh=...)``) yields per-device programs with **zero
collectives**.  This benchmark holds the *per-device* load fixed
(``--per-device`` batch elements of a ``--clients x --osts`` mixed
scenario each) and grows the device count, so ideal weak scaling is a
flat time — i.e. tuned interface-intervals/sec growing linearly with
devices.

On CPU the device counts are forced host devices
(``--xla_force_host_platform_device_count``, set *before* jax imports —
the reason this file parses argv at the top).  Forced host devices share
the machine's physical cores: on a single-core box the shards serialize
and the curve is flat-per-device rather than linear — the number to
trust there is the per-interface cost and the max-fleet capacity, and
the curve itself on multi-core hardware.

The second phase lifts one mesh over *all* forced devices to the target
fleet size (``--max-fleet`` interfaces, default 2^17) and completes a
multi-interval tuned run — the O(10^5) capacity probe.

Run:  PYTHONPATH=src python benchmarks/fleet_weak_scaling.py
          [--devices 1 2 4 8] [--per-device 64] [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

TICKS_PER_INTERVAL = 100   # 0.5 s tuning interval at the 5 ms tick
N_INTERVALS = 4            # tuned intervals per timed run


def _parse(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, nargs="*", default=None,
                    help="device counts to sweep (default 1 2 4 8; "
                         "quick: 1 2)")
    ap.add_argument("--per-device", type=int, default=None,
                    help="batch elements per device (default 64; "
                         "quick: 8)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--osts", type=int, default=2)
    ap.add_argument("--max-fleet", type=int, default=None,
                    help="capacity-probe target in interfaces "
                         "(default 2^17; quick: 4096; 0 disables)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 device points, small loads")
    ap.add_argument("--json", action="store_true",
                    help="emit the result dict as one final JSON line")
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = _parse(argv)
    devices = args.devices or ([1, 2] if args.quick else [1, 2, 4, 8])
    per_device = args.per_device or (8 if args.quick else 64)
    max_fleet = (args.max_fleet if args.max_fleet is not None
                 else (4096 if args.quick else 1 << 17))

    # forced host devices must be configured before jax initializes;
    # respect a count the caller already forced (e.g. the CI job)
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{max(devices)}").strip()

    import numpy as np

    import jax

    from repro.distributed.sharding import fleet_mesh
    from repro.pfs.loop_jax import FusedLoop
    from repro.pfs.workloads import table_from_sim

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from fleet_scaling import get_model
    from loop_scaling import build_sim

    n_avail = jax.device_count()
    devices = [d for d in devices if d <= n_avail]

    model = get_model("jax")
    sim = build_sim(args.clients, args.osts)
    n = sim.n_osc
    table, wstate0 = table_from_sim(sim)
    elem = (table, sim.state, wstate0)

    def lifted(b):
        """One scenario element tiled to a (b, ...) batch."""
        return jax.tree.map(
            lambda a: np.repeat(np.asarray(a)[None], b, axis=0), elem)

    def timed_run(n_dev: int, b: int) -> float:
        mesh = fleet_mesh(n_dev)
        loop = FusedLoop(sim.params, sim.topo, TICKS_PER_INTERVAL, model,
                         seg_backend="jax", batched=True, mesh=mesh)
        bt, bs, bw = lifted(b)
        loop.run(bt, bs, bw, N_INTERVALS)         # compile + warm
        t0 = time.perf_counter()
        loop.run(bt, bs, bw, N_INTERVALS)
        return time.perf_counter() - t0

    print(f"weak scaling: {per_device} elements/device x {n} interfaces, "
          f"{N_INTERVALS} x {TICKS_PER_INTERVAL}-tick tuned intervals "
          f"(compile excluded); {n_avail} devices visible, "
          f"{os.cpu_count()} host cores")
    print(f"{'devices':>8} {'interfaces':>11} {'s/run':>8} "
          f"{'if-intervals/s':>15} {'vs 1 dev':>9}")
    points, base_rate = [], None
    for d in devices:
        b = d * per_device
        t = timed_run(d, b)
        rate = b * n * N_INTERVALS / t
        base_rate = base_rate if base_rate is not None else rate
        points.append({"devices": d, "batch": b, "interfaces": b * n,
                       "seconds": round(t, 4),
                       "if_intervals_per_s": round(rate),
                       "speedup_vs_1dev": round(rate / base_rate, 2)})
        print(f"{d:>8} {b * n:>11} {t:>8.3f} {rate:>15.0f} "
              f"{rate / base_rate:>8.2f}x")

    probe = None
    if max_fleet:
        d = max(devices)
        b = max(max_fleet // n, d)
        b += (-b) % d                              # divisible fleet
        t = timed_run(d, b)
        rate = b * n * N_INTERVALS / t
        probe = {"devices": d, "interfaces": b * n,
                 "intervals": N_INTERVALS, "seconds": round(t, 3),
                 "if_intervals_per_s": round(rate)}
        print(f"max-fleet probe: {b * n} interfaces on {d} device(s), "
              f"{N_INTERVALS} tuned intervals in {t:.2f} s "
              f"({rate:.0f} if-intervals/s)")

    if args.json:
        print(json.dumps({"schema": "dial-weak-scaling-v1",
                          "interfaces_per_element": n,
                          "per_device_elements": per_device,
                          "host_cores": os.cpu_count(),
                          "points": points, "max_fleet": probe}))


if __name__ == "__main__":
    main()
