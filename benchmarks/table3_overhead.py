"""Paper Table III: DIAL execution overheads per OSC interface.

Wall-clock times for snapshot creation, model inference over the whole
configuration space, and the end-to-end tuning round — per operation type,
for the numpy reference backend, the jitted JAX path, and the Pallas
kernel (interpret mode on CPU; compiled on TPU).

Since the fleet refactor, :class:`DIALAgent` scores all of its client's
interfaces per tick in one batch, so the reported figures are the batch
cost amortized per interface — the honest per-interface price an
operator pays.  ``benchmarks/fleet_scaling.py`` sweeps the same figure
against the historical per-interface loop at fleet scale.
"""

from __future__ import annotations

import numpy as np

from repro.core.agent import DIALAgent, SimClientPort
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream


def run(model_path: str = "models/dial", backend: str = "numpy",
        seconds: float = 20.0) -> dict:
    model = DIALModel.load(model_path)
    model.backend = backend
    sim = PFSSim(n_clients=1, n_osts=2, seed=3)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0, n_threads=4))
    sim.attach(random_stream(0, WRITE, 64 * 1024, ost=1, n_threads=4))
    agent = DIALAgent(SimClientPort(sim, 0), model, measure_overhead=True)
    steps = int(round(0.5 / sim.params.tick))
    for _ in range(int(seconds / 0.5)):
        for _ in range(steps):
            sim.step()
        agent.tick()
    out = {}
    for op, name in ((READ, "read"), (WRITE, "write")):
        out[name] = agent.timings[op].summary()
    return out


def _fused_sim():
    sim = PFSSim(n_clients=2, n_osts=2, seed=3)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0, n_threads=4))
    sim.attach(random_stream(1, WRITE, 64 * 1024, ost=1, n_threads=4))
    return sim


def run_fused(model_path: str = "models/dial", sharded: bool = False,
              seconds: float = 20.0, interval: float = 0.5) -> dict:
    """Table III analog for the device-resident paths.

    The fused loop admits no per-stage host timing — the whole run is
    one dispatch — so the honest per-interface figure is differential:
    wall time of the tuned dispatch minus the engine-only dispatch,
    amortized over the (interface × interval) decisions it covered.
    Each loop is dispatched twice on fresh state; the second call is
    the compiled-program cost (the first includes compilation, reported
    separately as ``compile_s``).  ``sharded=True`` times the
    ``shard_map`` program over the local device mesh instead.
    """
    import jax

    from repro.core.model import DIALModel
    from repro.pfs.loop_jax import FusedLoop
    from repro.pfs.workloads import table_from_sim

    model = DIALModel.load(model_path)
    model.backend = "jax"
    sim = _fused_sim()
    steps = max(int(round(interval / sim.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    mesh = None
    if sharded:
        from repro.distributed.sharding import fleet_mesh
        mesh = fleet_mesh()
    lift = (lambda tree: jax.tree.map(
        lambda a: np.stack([np.asarray(a)]), tree)) if sharded else \
        (lambda tree: tree)

    import time as _time
    out = {}
    for name, tuned in (("tuned", True), ("engine_only", False)):
        loop = FusedLoop(sim.params, sim.topo, steps,
                         model if tuned else None, seg_backend="jax",
                         tuned=tuned, batched=sharded, mesh=mesh)
        walls = []
        for rep in range(2):            # rep 0 pays compilation
            s = _fused_sim()
            table, wstate = table_from_sim(s)
            t0 = _time.perf_counter()
            loop.run(lift(table), lift(s.state), lift(wstate),
                     n_intervals)
            walls.append(_time.perf_counter() - t0)
        out[name] = {"compile_s": round(walls[0] - walls[1], 3),
                     "execute_s": round(walls[1], 3),
                     "phases": loop.timers.summary()}
    per_if = (out["tuned"]["execute_s"] - out["engine_only"]["execute_s"]) \
        / (n_intervals * sim.n_osc) * 1e3
    out["tuning_ms_per_interface_interval"] = round(per_if, 4)
    out["n_intervals"] = n_intervals
    out["n_interfaces"] = sim.n_osc
    return out


def main():
    for backend in ("numpy", "jax", "pallas"):
        res = run(backend=backend)
        for op in ("read", "write"):
            r = res[op]
            print(f"[{backend:7s}] {op:5s}: snapshot={r['snapshot_ms']:6.2f} ms  "
                  f"inference={r['inference_ms']:6.2f} ms  "
                  f"end-to-end={r['end_to_end_ms']:6.2f} ms")
    for sharded in (False, True):
        rf = run_fused(sharded=sharded, seconds=10.0)
        tag = "jax-sharded" if sharded else "jax-fused"
        print(f"[{tag:11s}] tuning={rf['tuning_ms_per_interface_interval']:.4f} ms"
              f"/interface/interval  (tuned exec {rf['tuned']['execute_s']:.2f} s, "
              f"engine-only {rf['engine_only']['execute_s']:.2f} s, "
              f"compile {rf['tuned']['compile_s']:.2f} s)")
    print("(paper Table III: read 0.33/10.06/24.64 ms, "
          "write 0.85/13.51/28.82 ms on a 16-core host)")


if __name__ == "__main__":
    main()
