"""Paper Table III: DIAL execution overheads per OSC interface.

Wall-clock times for snapshot creation, model inference over the whole
configuration space, and the end-to-end tuning round — per operation type,
for the numpy reference backend, the jitted JAX path, and the Pallas
kernel (interpret mode on CPU; compiled on TPU).

Since the fleet refactor, :class:`DIALAgent` scores all of its client's
interfaces per tick in one batch, so the reported figures are the batch
cost amortized per interface — the honest per-interface price an
operator pays.  ``benchmarks/fleet_scaling.py`` sweeps the same figure
against the historical per-interface loop at fleet scale.
"""

from __future__ import annotations

import numpy as np

from repro.core.agent import DIALAgent, SimClientPort
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream


def run(model_path: str = "models/dial", backend: str = "numpy",
        seconds: float = 20.0) -> dict:
    model = DIALModel.load(model_path)
    model.backend = backend
    sim = PFSSim(n_clients=1, n_osts=2, seed=3)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0, n_threads=4))
    sim.attach(random_stream(0, WRITE, 64 * 1024, ost=1, n_threads=4))
    agent = DIALAgent(SimClientPort(sim, 0), model, measure_overhead=True)
    steps = int(round(0.5 / sim.params.tick))
    for _ in range(int(seconds / 0.5)):
        for _ in range(steps):
            sim.step()
        agent.tick()
    out = {}
    for op, name in ((READ, "read"), (WRITE, "write")):
        out[name] = agent.timings[op].summary()
    return out


def main():
    for backend in ("numpy", "jax", "pallas"):
        res = run(backend=backend)
        for op in ("read", "write"):
            r = res[op]
            print(f"[{backend:7s}] {op:5s}: snapshot={r['snapshot_ms']:6.2f} ms  "
                  f"inference={r['inference_ms']:6.2f} ms  "
                  f"end-to-end={r['end_to_end_ms']:6.2f} ms")
    print("(paper Table III: read 0.33/10.06/24.64 ms, "
          "write 0.85/13.51/28.82 ms on a 16-core host)")


if __name__ == "__main__":
    main()
