"""Ragged catalog economics: padded-ragged vs per-structure vs sequential.

A heterogeneous scenario catalog (mixed topologies, mixed workload-table
shapes from the PR-6 fuzz generator) historically paid one fused
dispatch — and one compiled program family — per *scenario*, or at best
per exact structure bucket.  Ragged pad-and-mask batching
(:func:`repro.lab.batch.bucket_scenarios`) collapses the catalog into
one dispatch per padded shape class.  This sweep drives the identical
tuned physics through all three groupings:

    sequential   one fused ``run_batch`` per scenario;
    structure    one per exact structure bucket (``ragged=False``);
    ragged       one per padded shape-class bucket (pad-and-mask).

reporting, per mode: fused dispatches, new compiled-loop instances
(cache misses on the cold pass — the cache persists across modes, so a
mode that reuses an earlier mode's wiring shows 0), and completed
scenario-seconds of simulation per wall-clock second on the warm pass
(compile excluded; per-element results are bit-equal across modes —
tests/test_ragged.py).

Run:  PYTHONPATH=src python benchmarks/ragged_scaling.py [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.lab.batch import (bucket_scenarios, loop_cache_stats,
                             reset_loop_cache_stats, run_batch,
                             stack_scenarios)
from repro.lab.fuzz import FuzzConfig, generate_spec
from repro.lab.scenarios import build
from repro.pfs.state import READ, WRITE

SECONDS = 1.0              # 2 tuning intervals per scenario
INTERVAL = 0.5

#: catalog generator: four topology classes and fuzz-drawn workload
#: tables, so scenario count >> structure count >> pad-class count
CATALOG = FuzzConfig(seed=7, min_events=1, max_events=2)


def _tiny_model(k: int = 1) -> DIALModel:
    """A small self-contained forest pair — the sweep benchmarks
    dispatch structure, not model quality."""
    rng = np.random.default_rng(0)

    def forest(dim):
        x = rng.normal(size=(400, dim)).astype(np.float32)
        y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
        return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(
            x, y).forest

    return DIALModel(read_forest=forest(feature_dim(READ, k)),
                     write_forest=forest(feature_dim(WRITE, k)),
                     backend="jax", k=k)


def _groups(specs, mode: str):
    """The catalog regrouped for one execution mode (fresh state)."""
    built = [build(s) for s in specs]
    if mode == "sequential":
        return [stack_scenarios([b]) for b in built]
    return [batch for _, batch in
            bucket_scenarios(built, ragged=(mode == "ragged"))]


def _drive(groups, model, seg_backend: str) -> None:
    for batch in groups:
        run_batch(batch, model=model, seconds=SECONDS, interval=INTERVAL,
                  seg_backend=seg_backend, fused=True)


def bench(n_scenarios: int, seg_backend: str = "jax",
          model: DIALModel | None = None) -> dict:
    specs = [generate_spec(CATALOG, i) for i in range(n_scenarios)]
    model = model or _tiny_model()
    sim_seconds = n_scenarios * SECONDS
    out = {"n_scenarios": n_scenarios}
    for mode in ("sequential", "structure", "ragged"):
        groups = _groups(specs, mode)
        reset_loop_cache_stats()
        _drive(groups, model, seg_backend)       # cold: misses counted
        misses = loop_cache_stats()["misses"]
        groups = _groups(specs, mode)
        t0 = time.perf_counter()
        _drive(groups, model, seg_backend)       # warm: cache hits only
        t = time.perf_counter() - t0
        out[f"{mode}_dispatches"] = len(groups)
        out[f"{mode}_loop_misses"] = misses
        out[f"{mode}_sim_s_per_s"] = sim_seconds / max(t, 1e-12)
        out[f"_{mode}_wall_s"] = t
    out["ragged_speedup_vs_seq"] = (out.pop("_sequential_wall_s")
                                    / max(out["_ragged_wall_s"], 1e-12))
    out["ragged_speedup_vs_structure"] = (out.pop("_structure_wall_s")
                                          / max(out.pop("_ragged_wall_s"),
                                                1e-12))
    return out


def run(scales=(8, 16, 32), seg_backend: str = "jax") -> list[dict]:
    model = _tiny_model()
    return [bench(n, seg_backend, model=model) for n in scales]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--catalogs", type=int, nargs="*", default=[8, 16, 32])
    ap.add_argument("--seg-backend", default="jax")
    ap.add_argument("--quick", action="store_true",
                    help="sweep 8..16 scenarios only")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    scales = ([n for n in args.catalogs if n <= 16] if args.quick
              else args.catalogs)

    print(f"mixed-catalog tuning, {SECONDS:.0f} s per scenario at "
          f"{INTERVAL} s intervals (fused; warm pass timed, loop-cache "
          f"misses counted on the cold pass)")
    print(f"{'N':>4} {'mode':>10} {'dispatch':>8} {'loopmiss':>8} "
          f"{'sim-s/s':>10}")
    rows = []
    model = _tiny_model()
    for n in scales:
        r = bench(n, args.seg_backend, model=model)
        rows.append(r)
        for mode in ("sequential", "structure", "ragged"):
            print(f"{n:>4} {mode:>10} {r[f'{mode}_dispatches']:>8} "
                  f"{r[f'{mode}_loop_misses']:>8} "
                  f"{r[f'{mode}_sim_s_per_s']:>9.1f}")
        print(f"     ragged speedup: {r['ragged_speedup_vs_seq']:.1f}x vs "
              f"sequential, {r['ragged_speedup_vs_structure']:.1f}x vs "
              f"per-structure")
    if args.json:
        for r in rows:
            print(json.dumps({"schema": "dial-ragged-scaling-v1", **r}))


if __name__ == "__main__":
    main()
