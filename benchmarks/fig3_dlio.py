"""Paper Fig. 3: deep-learning I/O kernels (DLIO) — DIAL vs default.

BERT- and Megatron-style readers across OST utilization x thread counts.
The paper reports up to 1.75x over the default configuration.
"""

from __future__ import annotations

from repro.core.agent import run_with_agents
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.workloads import dlio_reader

SECONDS = 25.0

CASES = [
    # (model, n_threads, n_osts_used)
    ("bert", 2, 1), ("bert", 8, 1), ("bert", 16, 2), ("bert", 32, 4),
    ("megatron", 2, 1), ("megatron", 8, 1), ("megatron", 16, 2),
    ("megatron", 32, 4),
]


def _run(model_name, threads, osts, dial_model=None, seed=13):
    sim = PFSSim(n_clients=1, n_osts=8, seed=seed)
    wl = dlio_reader(0, model_name, threads, osts=tuple(range(osts)))
    sim.attach(wl)
    # Lustre defaults
    sim.set_knobs(sim.client_oscs(0), window_pages=256, rpcs_in_flight=8)
    if dial_model is not None:
        run_with_agents(sim, dial_model, [0], SECONDS)
    else:
        sim.run(SECONDS)
    return wl.done_bytes(sim) / SECONDS / 1e6


def run(model_path: str = "models/dial") -> list[dict]:
    model = DIALModel.load(model_path)
    rows = []
    for m, t, o in CASES:
        base = _run(m, t, o)
        dial = _run(m, t, o, dial_model=model)
        rows.append({"kernel": m, "threads": t, "osts": o,
                     "default_mbs": round(base, 1),
                     "dial_mbs": round(dial, 1),
                     "speedup": round(dial / max(base, 1e-9), 2)})
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"DLIO-{r['kernel']:9s} t={r['threads']:2d} osts={r['osts']}: "
              f"default={r['default_mbs']:7.1f}  DIAL={r['dial_mbs']:7.1f}  "
              f"({r['speedup']:.2f}x)")
    best = max(r["speedup"] for r in rows)
    print(f"max speedup over default: {best:.2f}x (paper: up to 1.75x)")


if __name__ == "__main__":
    main()
